//! Technology-sensitivity ablation: how the DSE's Pareto selections shift
//! as the technology constants move away from the 32nm calibration —
//! answering "does the paper's conclusion (SEP/HY-PG win, SMP loses)
//! survive model error?" (DESIGN.md section 7 commits to order-correctness,
//! this sweep demonstrates it).
//!
//!   cargo run --release --example dse_sweep
//!
//! Sweeps leakage (±4x), DRAM energy (±4x) and the multi-port energy
//! exponent, rerunning the full CapsNet DSE each time; writes
//! results/dse_sweep.csv.

use descnet::cacti::cache;
use descnet::config::{SystemConfig, Technology};
use descnet::ctx::EvalCtx;
use descnet::dataflow::profile_network;
use descnet::dse;
use descnet::model::capsnet_mnist;
use descnet::util::csv::{f, s, Csv};

fn run_one(label: &str, tech: &Technology, csv: &mut Csv) {
    let cfg = SystemConfig::default();
    let profile = profile_network(&capsnet_mnist(), &cfg.accel);
    let ctx = EvalCtx::new(tech.clone(), cfg.accel.clone());
    let result = dse::run(&ctx, &profile).expect("DSE sweep");
    let sel: std::collections::BTreeMap<_, _> = result.selected.iter().cloned().collect();
    let frontier_opts: std::collections::BTreeSet<String> =
        result.pareto.iter().map(|&i| result.points[i].option().to_string()).collect();

    let hy_pg = &result.points[sel["HY-PG"]];
    let sep = &result.points[sel["SEP"]];
    let smp = &result.points[sel["SMP"]];
    // The paper's structural conclusions, re-checked per technology point:
    let hy_pg_near_best = result
        .selected
        .iter()
        .all(|(_, i)| hy_pg.energy_j <= result.points[*i].energy_j * 1.05);
    let sep_lowest_area = result
        .selected
        .iter()
        .all(|(_, i)| sep.area_mm2 <= result.points[*i].area_mm2 * 1.001);
    csv.row(vec![
        s(label),
        f(sep.energy_j * 1e3),
        f(hy_pg.energy_j * 1e3),
        f(smp.energy_j * 1e3),
        f(sep.area_mm2),
        f(hy_pg.area_mm2),
        f(smp.area_mm2),
        s(if hy_pg_near_best { "1" } else { "0" }),
        s(if sep_lowest_area { "1" } else { "0" }),
        s(if frontier_opts.contains("SMP") { "1" } else { "0" }),
    ]);
    println!(
        "{label:28}  HY-PG {:8.3} mJ  SEP {:8.3} mJ  SMP {:8.3} mJ  [hy-best={} sep-area={} smp-on-frontier={}]",
        hy_pg.energy_j * 1e3,
        sep.energy_j * 1e3,
        smp.energy_j * 1e3,
        hy_pg_near_best,
        sep_lowest_area,
        frontier_opts.contains("SMP"),
    );
}

fn main() {
    let mut csv = Csv::new(&[
        "tech_point",
        "sep_mj",
        "hy_pg_mj",
        "smp_mj",
        "sep_mm2",
        "hy_pg_mm2",
        "smp_mm2",
        "hy_pg_near_best",
        "sep_lowest_area",
        "smp_on_frontier",
    ]);

    run_one("baseline-32nm", &Technology::default(), &mut csv);

    for scale in [0.25, 0.5, 2.0, 4.0] {
        let mut t = Technology::default();
        t.sram_leak_w_per_byte *= scale;
        run_one(&format!("leakage x{scale}"), &t, &mut csv);
    }
    for scale in [0.25, 0.5, 2.0, 4.0] {
        let mut t = Technology::default();
        t.dram_j_per_byte *= scale;
        run_one(&format!("dram-energy x{scale}"), &t, &mut csv);
    }
    for exp in [1.2, 1.7, 2.0] {
        let mut t = Technology::default();
        t.sram_dyn_port_exp = exp;
        run_one(&format!("port-exp {exp}"), &t, &mut csv);
    }

    let out = std::path::PathBuf::from("results/dse_sweep.csv");
    csv.write_file(&out).expect("writing results");
    println!("wrote {}", out.display());
    // Each perturbed technology gets its own cache namespace; the entry
    // count stays small because the sweep reuses the same geometry pools.
    println!(
        "cacti cache: {} geometries, {} hits / {} misses",
        cache::global().len(),
        cache::global().hits(),
        cache::global().misses(),
    );
}
