//! Multi-network co-design walkthrough: one scratchpad organization sized
//! and selected across a workload *set* — the two paper benchmarks, a
//! batched CapsNet scenario, and a seeded random NASCaps-style network.
//!
//!   cargo run --release --example multi_workload_dse
//!
//! Equivalent CLI: `descnet dse --net capsnet,deepcaps --random 1 --seed 42`
//! (add `--batch 4` to profile every member at batch 4, or
//! `--workload configs/workloads/edge_serving_mix.json` for a spec-file
//! mix with explicit serving weights).

use descnet::config::SystemConfig;
use descnet::ctx::EvalCtx;
use descnet::dataflow::{profile_network, profile_network_batched};
use descnet::dse::multi::{self, WorkloadSet};
use descnet::model::{capsnet_mnist, deepcaps_cifar10, random_network};
use descnet::util::units::{fmt_energy, fmt_size};

fn main() {
    let cfg = SystemConfig::default();

    // 1. The workload set: four scenarios sharing one accelerator.
    let rand_net = random_network(42);
    let profiles = vec![
        profile_network(&capsnet_mnist(), &cfg.accel),
        profile_network(&deepcaps_cifar10(), &cfg.accel),
        profile_network_batched(&capsnet_mnist(), &cfg.accel, 4),
        profile_network(&rand_net, &cfg.accel),
    ];
    let names = ["capsnet", "deepcaps", "capsnet@b4", "rand-42"];
    for (n, p) in names.iter().zip(&profiles) {
        println!(
            "{n:12} {:2} ops  D {:>9}  W {:>9}  A {:>9}  {:7.1} fps",
            p.ops.len(),
            fmt_size(p.max_d()),
            fmt_size(p.max_w()),
            fmt_size(p.max_a()),
            p.fps(),
        );
    }

    // 2. Serving mix: capsnet dominates the traffic.
    let set = WorkloadSet::with_weights(profiles, vec![0.5, 0.1, 0.3, 0.1])
        .expect("valid workload set");

    // 3. Co-design: union sizing, mix-weighted energy objective, the usual
    //    Pareto / per-option selection.
    let result = multi::run(&EvalCtx::for_config(&cfg), &set).expect("co-design DSE");
    println!(
        "\nco-design space: {} organizations, {} on the Pareto frontier",
        result.points.len(),
        result.pareto.len()
    );
    for (option, idx) in &result.selected {
        let p = &result.points[*idx];
        let per_net: Vec<String> = result.per_net_j[*idx]
            .iter()
            .zip(names)
            .map(|(e, n)| format!("{n} {}", fmt_energy(*e)))
            .collect();
        println!(
            "  {option:7}  area {:6.3} mm²  E-mix {}  [{}]",
            p.area_mm2,
            fmt_energy(p.energy_j),
            per_net.join(", ")
        );
    }

    // 4. The organization a serving deployment would instantiate.
    let best = result.codesigned().expect("non-empty selection");
    println!(
        "\nco-designed organization: {} ({} total on-chip SPM)",
        result.points[best].org.label(),
        fmt_size(result.points[best].org.total_size()),
    );
}
